"""Live updates: the mutable-store write path, end to end.

A warm SPARQL query survives INSERT DATA / DELETE DATA and a compaction
without a single recompile — tail rows and tombstone masks ride inside
the already-compiled scan buckets, and capacity floors keep the plan
shape stable across compact(). Run:

    PYTHONPATH=src python examples/live_updates.py
"""
from repro.sparql.engine import QueryEngine
from repro.sparql.store import store_from_string_triples

store = store_from_string_triples([
    ("<anny>", "<hasJob>", "<professor>"),
    ("<jim>", "<hasJob>", "<doctor>"),
    ("<susan>", "<hasJob>", "<nurse>"),
    ("<doctor>", "<workAt>", "<hospital>"),
    ("<nurse>", "<workAt>", "<hospital>"),
])
engine = QueryEngine(store)

text = """SELECT ?person ?job WHERE {
    ?person <hasJob> ?job .
    ?job <workAt> <hospital> .
}"""

# --- 1. warm the shape: calibrate + compile once, then one dispatch -----
pq = engine.prepare(text)
pq.run()
warm = pq.run()
assert warm.stats.n_compiles == 0 and warm.stats.n_dispatches == 1
print(f"warm result (v{store.version}):", sorted(
    r["?person"] for r in warm.rows))

# --- 2. write through the update path: set semantics, typed result ------
res = engine.update("""
    INSERT DATA { <bob> <hasJob> <doctor> . <bob> <hasJob> <doctor> } ;
    DELETE DATA { <susan> <hasJob> <nurse> }
""")
print(f"update: inserted={res.inserted} deleted={res.deleted} "
      f"(duplicate insert skipped) -> store v{res.version}")

# --- 3. the warm handle sees the new snapshot, still 0 compiles ---------
after = pq.run()
assert after.stats.n_compiles == 0 and after.stats.n_dispatches == 1
assert after.stats.store_version == store.version
print(f"after writes (v{store.version}):", sorted(
    r["?person"] for r in after.rows))
assert sorted(r["?person"] for r in after.rows) == ["<bob>", "<jim>"]

ws = store.write_stats()
print(f"delta state: base={ws['base_rows']} tail={ws['tail_rows']} "
      f"tombstones={ws['tombstones']}")

# --- 4. compact: fold the delta into new base blocks --------------------
store.compact()
ws = store.write_stats()
print(f"compacted: base={ws['base_rows']} tail={ws['tail_rows']} "
      f"tombstones={ws['tombstones']} (compaction #{ws['compactions']})")

# capacity floors survive compaction: the same executable still serves
compacted = pq.run()
assert compacted.stats.n_compiles == 0 and compacted.stats.n_dispatches == 1
assert compacted.rows == after.rows
print("post-compaction rerun: 0 compiles, 1 dispatch, same rows")

# --- 5. differential check: a store rebuilt from scratch agrees ---------
d = store.dictionary
rebuilt = store_from_string_triples(sorted(
    (d.decode(int(s)), d.decode(int(p)), d.decode(int(o)))
    for s, p, o in store.triples))
assert sorted(map(tuple, map(sorted, map(dict.items, (
    QueryEngine(rebuilt).query(text)))))) == sorted(
    map(tuple, map(sorted, map(dict.items, compacted.rows))))
print("rebuilt-from-scratch store agrees")
print("LIVE UPDATES OK")
