"""End-to-end driver (the paper's kind: a query-serving system).

Generates a LUBM-style store, stands up the MapSQ engine (compiled
one-dispatch pipeline + plan/compile cache) behind the micro-batching
server, fires the 5 benchmark queries concurrently — twice, so the second
round exercises the warm cache — and cross-checks every result set against
the CPU hash-join baseline.

    PYTHONPATH=src python examples/sparql_lubm.py [scale]
"""
import sys
import threading
import time

from repro.core.planner import plan_bgp
from repro.serve.sparql_server import SPARQLServer
from repro.sparql.baseline import hash_join
from repro.sparql.engine import QueryEngine
from repro.sparql.lubm import QUERIES, generate
from repro.sparql.parser import parse

scale = int(sys.argv[1]) if len(sys.argv) > 1 else 2
t0 = time.time()
store = generate(scale=scale)
print(f"store: {len(store)} triples, {len(store.dictionary)} terms "
      f"({time.time() - t0:.1f}s)")

engine = QueryEngine(store)
server = SPARQLServer(engine, max_batch=4)

results: dict[str, list] = {}


def ask(name: str, text: str) -> None:
    t = time.time()
    rows = server.query(text)
    results[name] = rows
    print(f"  {name}: {len(rows)} rows in {time.time() - t:.3f}s")


print("running 5 LUBM queries through the batching server (round 1 = cold:"
      " calibrate + compile; round 2 = warm: one dispatch per query):")
for rnd in (1, 2):
    print(f" round {rnd}:")
    threads = [threading.Thread(target=ask, args=(n, t))
               for n, t in QUERIES.items()]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
stats = server.stats()
print("server stats:", stats)
print(f"plan-cache hit rate: {stats['plan_cache']['hit_rate']:.0%} "
      f"({stats['plan_cache']['compiles']} compiles for "
      f"{stats['requests']} requests)")
server.close()

# prepared-query API: FILTER + OPTIONAL + LIMIT compiled into one program;
# explain() shows the algebra, the physical plan and the cache state
prepared = engine.prepare(
    "PREFIX ub: <http://swat.cse.lehigh.edu/onto/univ-bench.owl#>\n"
    "SELECT ?s ?d ?a WHERE {\n"
    "  ?s ub:memberOf ?d .          # required pattern\n"
    "  OPTIONAL { ?s ub:advisor ?a }\n"
    "  FILTER (?s != ?a)\n"
    "} LIMIT 20"
)
print("\nprepared FILTER+OPTIONAL+LIMIT query, before the first run:")
print(prepared.explain())
rs = prepared.run()
print(f"-> {len(rs)} rows; cold run: {rs.stats.n_compiles} compile(s)")
rs = prepared.run()
print(f"-> warm run: {rs.stats.n_compiles} compiles, "
      f"{rs.stats.n_dispatches} dispatch")
print(prepared.explain().splitlines()[-3])  # cache: compiled, buckets=...

# the cost-based optimizer at work: a UNION query with a pushed filter
# (distributed into both branches) — and the J1 bad-join-order shape, where
# the statistics-driven order keeps the max join bucket ~32x smaller than
# the greedy order (run with join_shapes=True stores to see J1/J2 data)
union_pq = engine.prepare(
    "PREFIX ub: <http://swat.cse.lehigh.edu/onto/univ-bench.owl#>\n"
    "SELECT ?s ?v WHERE {\n"
    "  ?s a ub:GraduateStudent .\n"
    "  { ?s ub:advisor ?v } UNION { ?s ub:memberOf ?v }\n"
    "  FILTER (?v != <http://example.org/Dept0_0>)\n"
    "}"
)
rs = union_pq.run()
rs = union_pq.run()
print(f"\nUNION + pushed filter: {len(rs)} rows, warm run = "
      f"{rs.stats.n_dispatches} dispatch / {rs.stats.n_compiles} compiles")
print("optimizer trace:")
for line in union_pq.explain().splitlines():
    if "join_order" in line or "filter_pushdown" in line:
        print(" ", line.strip())

# warm restarts: persist the learned bucket signatures; a new engine with
# warmup_path compiles known shapes directly, skipping calibration
n = engine.save_cache("/tmp/mapsq-warmup.json")
print(f"saved {n} plan signatures for warm restart "
      "(QueryEngine(warmup_path=...))")

# cross-check every query against the CPU hash-join baseline
print("validating against the hash-join baseline:")
for name, text in QUERIES.items():
    q = parse(text)
    steps = plan_bgp(q.patterns, store.estimate_cardinality)
    parts = [store.match_pattern(q.patterns[s.pattern_index]) for s in steps]
    sch, rows = parts[0].schema, parts[0].to_numpy()
    for p in parts[1:]:
        sch, rows = hash_join(sch, rows, p.schema, p.to_numpy())
    # project to the query's projection, compare as sets
    proj = q.projection()
    idx = [sch.index(v) for v in proj]
    want = {tuple(int(r[i]) for i in idx) for r in rows}
    d = store.dictionary
    got = {tuple(d.lookup(row[v]) for v in proj) for row in results[name]}
    assert got == want, f"{name}: engine != baseline"
    print(f"  {name}: OK ({len(want)} unique rows)")
print("ALL QUERIES VALIDATED")
