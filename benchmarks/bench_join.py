"""Table 2 / Figure 2 reproduction: join time of the 5 LUBM queries —
MapSQ's MapReduce join (device, jitted) vs the CPU-engine join class.

Baseline mapping (see sparql/baseline.py):
  gStore   → hash_join            (build/probe, the centralized CPU engine)
  gStoreD  → partitioned_hash_join (partition pass + local joins)
  (plain)  → nested_loop_join     (the paper's 'plain join algorithm';
                                    only run when inputs are small)

The numbers reproduce the COMPARISON SHAPE of Table 2 (same partial
matches in, same results out, join time measured); absolute ratios on this
CPU-only container are indicative, not TPU measurements — see EXPERIMENTS.md.
"""
from __future__ import annotations

import time

import numpy as np

import jax

from repro.core import mr_join as mj
from repro.core.planner import plan_bgp
from repro.sparql import lubm
from repro.sparql.baseline import (hash_join, nested_loop_join,
                                   partitioned_hash_join)
from repro.sparql.engine import QueryEngine
from repro.sparql.parser import parse
from repro.sparql.store import _next_pow2

NESTED_LOOP_MAX = 3000  # rows; python nested loop beyond this is pointless


def _time(fn, repeat=3, number=1) -> float:
    best = float("inf")
    for _ in range(repeat):
        t0 = time.perf_counter()
        for _ in range(number):
            fn()
        best = min(best, (time.perf_counter() - t0) / number)
    return best


def _mapsq_join_chain(partials):
    """The jitted Algorithm-1 chain (count pass + expand pass per step)."""
    jit_count = jax.jit(mj.mr_join_count)
    jit_join = jax.jit(mj.mr_join, static_argnames=("capacity",))

    def run():
        acc = partials[0]
        for nxt in partials[1:]:
            total = int(jit_count(acc, nxt))
            cap = max(1, _next_pow2(total))
            acc, _, _ = jit_join(acc, nxt, capacity=cap)
        return acc.cols.block_until_ready()

    return run


def bench(scale: int = 3, seed: int = 0) -> list[dict]:
    store = lubm.generate(scale=scale, seed=seed)
    eng = QueryEngine(store)
    rows_out = []
    for name, text in lubm.QUERIES.items():
        q = parse(text)
        steps = plan_bgp(q.patterns, store.estimate_cardinality)
        partials = [store.match_pattern(q.patterns[s.pattern_index])
                    for s in steps]
        np_parts = [(p.schema, p.to_numpy()) for p in partials]
        sizes = [len(r) for _, r in np_parts]

        run_mapsq = _mapsq_join_chain(partials)
        run_mapsq()  # warm the jit cache: measure join time, not compile
        t_mapsq = _time(run_mapsq)

        def chain(join):
            def run():
                sch, rows = np_parts[0]
                for sch2, rows2 in np_parts[1:]:
                    sch, rows = join(sch, rows, sch2, rows2)
                return rows

            return run

        t_hash = _time(chain(hash_join))
        t_part = _time(chain(partitioned_hash_join))
        t_nested = (
            _time(chain(nested_loop_join), repeat=1)
            if max(sizes) <= NESTED_LOOP_MAX else float("nan")
        )
        n_result = len(chain(hash_join)())
        rows_out.append({
            "query": name,
            "inputs": "x".join(map(str, sizes)),
            "n_result": n_result,
            "gStore_ms": t_hash * 1e3,
            "gStoreD_ms": t_part * 1e3,
            "MapSQ_ms": t_mapsq * 1e3,
            "nested_ms": t_nested * 1e3,
            "SpeedUp_g": t_hash / t_mapsq,
            "SpeedUp_D": t_part / t_mapsq,
        })
    return rows_out


def main() -> None:
    print("# Table 2 reproduction: join time (ms), LUBM scale=3")
    print("query,inputs,n_result,gStore_ms,gStoreD_ms,MapSQ_ms,nested_ms,"
          "SpeedUp_g,SpeedUp_D")
    for r in bench():
        print(f"{r['query']},{r['inputs']},{r['n_result']},"
              f"{r['gStore_ms']:.2f},{r['gStoreD_ms']:.2f},"
              f"{r['MapSQ_ms']:.2f},{r['nested_ms']:.2f},"
              f"{r['SpeedUp_g']:.2f},{r['SpeedUp_D']:.2f}")


if __name__ == "__main__":
    main()
