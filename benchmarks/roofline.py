"""Roofline table builder: reads the dry-run JSONs (results/) and emits the
§Roofline markdown table — three terms per (arch × shape × mesh), dominant
bottleneck, MODEL_FLOPS/HLO_FLOPS usefulness ratio, and the headline
roofline fraction (useful-FLOPs time / dominant-term time).
"""
from __future__ import annotations

import glob
import json
import os

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9


def load(results_dir: str = "results") -> list[dict]:
    recs = []
    for path in sorted(glob.glob(os.path.join(results_dir, "*.json"))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def enrich(r: dict) -> dict:
    chips = r["chips"]
    t_useful = r["model_flops_global"] / (chips * PEAK_FLOPS)
    t_dom = max(r["t_compute"], r["t_memory"], r["t_collective"])
    r = dict(r)
    r["t_useful"] = t_useful
    r["t_dominant"] = t_dom
    r["roofline_fraction"] = t_useful / t_dom if t_dom else 0.0
    return r


def fmt_s(x: float) -> str:
    if x >= 1:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x * 1e3:.2f}ms"
    return f"{x * 1e6:.1f}us"


def table(recs: list[dict], mesh: str = "16x16") -> str:
    rows = [enrich(r) for r in recs if r["mesh"] == mesh]
    rows.sort(key=lambda r: (r["arch"], r["shape"]))
    out = [
        "| arch | shape | t_compute | t_memory(ub) | t_mem_io(lb) | "
        "t_collective | bottleneck | useful/HLO | roofline frac | HBM GiB |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        hbm = (r["memory"]["temp_bytes"] + r["memory"]["argument_bytes"]
               - r["memory"]["alias_bytes"]) / 2**30
        io = fmt_s(r["t_memory_io"]) if "t_memory_io" in r else "-"
        out.append(
            f"| {r['arch']} | {r['shape']} | {fmt_s(r['t_compute'])} | "
            f"{fmt_s(r['t_memory'])} | {io} | {fmt_s(r['t_collective'])} | "
            f"{r['bottleneck']} | {r['useful_flops_ratio']:.3f} | "
            f"{r['roofline_fraction']:.3f} | {hbm:.2f} |"
        )
    return "\n".join(out)


def main() -> None:
    recs = load()
    print(f"# Roofline (from {len(recs)} dry-run records)")
    for mesh in ("16x16", "2x16x16"):
        print(f"\n## mesh {mesh}\n")
        print(table(recs, mesh))
    worst = sorted((enrich(r) for r in recs if r["mesh"] == "16x16"),
                   key=lambda r: r["roofline_fraction"])
    print("\n## worst roofline fractions (hillclimb candidates)")
    for r in worst[:6]:
        print(f"  {r['arch']} x {r['shape']}: frac={r['roofline_fraction']:.4f}"
              f" bottleneck={r['bottleneck']}")
    coll = sorted((enrich(r) for r in recs if r["mesh"] == "16x16"),
                  key=lambda r: -(r["t_collective"] / max(r["t_dominant"],
                                                          1e-30)))
    print("\n## most collective-bound")
    for r in coll[:6]:
        print(f"  {r['arch']} x {r['shape']}: t_coll={fmt_s(r['t_collective'])}"
              f" vs dom={fmt_s(r['t_dominant'])}")


if __name__ == "__main__":
    main()
