"""Repeated-query throughput: eager per-join loop vs the compiled pipeline.

The eager engine pays, per join and per query, a jitted COUNT dispatch, a
host sync of the cardinality, and a jitted EXPAND dispatch (with a possible
recompile when the pow-2 capacity is new). The compiled pipeline pays
calibration + compilation ONCE per plan shape, then serves every repeat
with a single device dispatch from the plan/compile cache — the behaviour a
query-serving deployment actually sees.

Besides the 5 plain-BGP LUBM queries this also tracks the FILTER /
OPTIONAL / LIMIT operator shapes (F1, O1, FO1) so the perf trajectory
covers the full prepared-query algebra, not just join chains.

    PYTHONPATH=src python -m benchmarks.bench_query [scale] [repeats]
"""
from __future__ import annotations

import sys
import time

from repro.sparql import lubm
from repro.sparql.engine import QueryEngine

# operator-coverage shapes: device-side FILTER masks, OPTIONAL left joins
# with UNBOUND padding, and a LIMIT slice on top of both
EXTRA_QUERIES: dict[str, str] = {
    # F1: star BGP + string-identity and numeric-free filter
    "F1": lubm.PREFIX + """SELECT ?p ?n WHERE {
        ?p a ub:FullProfessor .
        ?p ub:name ?n .
        FILTER (?n != "prof_0_0_0")
    }""",
    # O1: wide type scan, optional advisor edge (some students unmatched)
    "O1": lubm.PREFIX + """SELECT ?s ?a WHERE {
        ?s a ub:GraduateStudent .
        OPTIONAL { ?s ub:advisor ?a }
    }""",
    # FO1: filter + optional + limit through one compiled program
    "FO1": lubm.PREFIX + """SELECT ?s ?d ?a WHERE {
        ?s ub:memberOf ?d .
        OPTIONAL { ?s ub:advisor ?a }
        FILTER (?s != ?a)
    } LIMIT 64""",
}


def _time(fn, repeat: int) -> float:
    t0 = time.perf_counter()
    for _ in range(repeat):
        fn()
    return (time.perf_counter() - t0) / repeat


def bench(scale: int = 2, repeats: int = 20, seed: int = 0) -> list[dict]:
    store = lubm.generate(scale=scale, seed=seed)
    eager = QueryEngine(store, compiled=False)
    compiled = QueryEngine(store)
    out = []
    queries = {**lubm.QUERIES, **EXTRA_QUERIES}
    for name, text in queries.items():
        # warm both: the eager jit cache and the compiled plan cache
        rows_e = eager.query(text)
        rows_c = compiled.query(text)
        assert len(rows_e) == len(rows_c), name
        t_eager = _time(lambda: eager.query(text), repeats)
        t_compiled = _time(lambda: compiled.query(text), repeats)
        out.append({
            "query": name,
            "rows": len(rows_c),
            "eager_ms": t_eager * 1e3,
            "compiled_ms": t_compiled * 1e3,
            "speedup": t_eager / t_compiled,
        })
    out.append({"plan_cache": compiled.cache_stats(),
                "scan_cache": store.scan_cache_stats()})
    return out


def main() -> None:
    scale = int(sys.argv[1]) if len(sys.argv) > 1 else 2
    repeats = int(sys.argv[2]) if len(sys.argv) > 2 else 20
    print(f"# repeated (warm) LUBM queries, scale={scale}, "
          f"{repeats} repeats: eager vs compiled one-dispatch pipeline")
    print("query,rows,eager_ms,compiled_ms,speedup")
    rows = bench(scale=scale, repeats=repeats)
    for r in rows:
        if "query" in r:
            print(f"{r['query']},{r['rows']},{r['eager_ms']:.2f},"
                  f"{r['compiled_ms']:.2f},{r['speedup']:.2f}")
        else:
            print(f"# {r}")


if __name__ == "__main__":
    main()
