"""Repeated-query throughput: eager per-join loop vs the compiled pipeline.

The eager engine pays, per join and per query, a jitted COUNT dispatch, a
host sync of the cardinality, and a jitted EXPAND dispatch (with a possible
recompile when the pow-2 capacity is new). The compiled pipeline pays
calibration + compilation ONCE per plan shape, then serves every repeat
with a single device dispatch from the plan/compile cache — the behaviour a
query-serving deployment actually sees.

Besides the 5 plain-BGP LUBM queries this also tracks the FILTER /
OPTIONAL / LIMIT / UNION operator shapes (F1, O1, FO1, U1) and the
bad-join-order shapes J1/J2, on which it additionally compares the
statistics-driven join order against the legacy greedy order and FAILS
(non-zero exit) if the optimizer stops producing strictly smaller maximum
join buckets — so planner regressions that explode intermediate sizes
fail the CI build (the bench-smoke job runs `--quick` on CPU).

B1/B2 measure batched same-shape execution: 16 / 64 warm queries of one
plan shape (differing only in a FILTER constant), run sequentially (N
dispatches) vs through engine.run_batch (ceil(N / width) stacked
dispatches). The dispatch count is asserted — it is the structural win and
is deterministic — and the timing ratio is reported; the batched records
are also written to the BENCH_4.json artifact.

W1 measures the live-update path: insert_triples ingest rate over a batch
size sweep, warm-query latency before / after in-headroom writes / after
compaction, and asserts the warm plan cache survives the whole sequence
(0 compiles, 1 dispatch) with results equal to a store rebuilt from
scratch. Records land in BENCH_7.json.

    PYTHONPATH=src python -m benchmarks.bench_query [scale] [repeats]
    PYTHONPATH=src python -m benchmarks.bench_query --quick
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import time

from repro.sparql import lubm
from repro.sparql.engine import QueryEngine

# operator-coverage shapes: device-side FILTER masks, OPTIONAL left joins
# with UNBOUND padding, a LIMIT slice, and a UNION concat
EXTRA_QUERIES: dict[str, str] = {
    # F1: star BGP + string-identity and numeric-free filter
    "F1": lubm.PREFIX + """SELECT ?p ?n WHERE {
        ?p a ub:FullProfessor .
        ?p ub:name ?n .
        FILTER (?n != "prof_0_0_0")
    }""",
    # O1: wide type scan, optional advisor edge (some students unmatched)
    "O1": lubm.PREFIX + """SELECT ?s ?a WHERE {
        ?s a ub:GraduateStudent .
        OPTIONAL { ?s ub:advisor ?a }
    }""",
    # FO1: filter + optional + limit through one compiled program
    "FO1": lubm.PREFIX + """SELECT ?s ?d ?a WHERE {
        ?s ub:memberOf ?d .
        OPTIONAL { ?s ub:advisor ?a }
        FILTER (?s != ?a)
    } LIMIT 64""",
    # U1: shared required scan, two union branches, one compiled dispatch
    "U1": lubm.PREFIX + """SELECT ?s ?v WHERE {
        ?s a ub:GraduateStudent .
        { ?s ub:advisor ?v } UNION { ?s ub:memberOf ?v }
    }""",
}


def _time(fn, repeat: int) -> float:
    t0 = time.perf_counter()
    for _ in range(repeat):
        fn()
    return (time.perf_counter() - t0) / repeat


# batched same-shape serving shapes: N queries of ONE plan shape, differing
# only in a FILTER constant (a runtime input — all share the compiled plan)
B_SHAPES = {"B1": 16, "B2": 64}


def _b_queries(n: int) -> list[str]:
    return [
        lubm.PREFIX + f"""SELECT ?p ?n WHERE {{
            ?p a ub:FullProfessor .
            ?p ub:name ?n .
            FILTER (?n != "prof_0_{k % 8}_{k // 8}")
        }}"""
        for k in range(n)
    ]


def bench_batched(store, repeats: int) -> list[dict]:
    """Sequential vs stacked execution of N warm same-shape queries.

    Asserts the dispatch count (ceil(N / width) — the deterministic
    structural win) and reports the wall-clock throughput ratio.
    """
    out = []
    for name, n in B_SHAPES.items():
        eng = QueryEngine(store)
        prepared = [eng.prepare(t) for t in _b_queries(n)]
        seq = [pq.run() for pq in prepared]  # warm plan cache (1 calib)
        stacked = eng.run_batch(prepared)  # warm stacked width
        assert [r.rows for r in stacked] == [r.rows for r in seq], name
        t_seq = _time(lambda: [pq.run() for pq in prepared], repeats)
        t_bat = _time(lambda: eng.run_batch(prepared), repeats)
        group = eng.last_batch[0]
        width = max(group.widths)
        want = -(-n // width)  # ceil
        assert group.n_dispatches == want, (
            f"{name}: {n} warm same-shape queries took "
            f"{group.n_dispatches} stacked dispatches, want {want}"
        )
        out.append({
            "query": name,
            "n_queries": n,
            "rows": len(seq[0]),
            "batch_width": width,
            "stacked_dispatches": group.n_dispatches,
            "sequential_ms": t_seq * 1e3,
            "stacked_ms": t_bat * 1e3,
            "throughput_x": t_seq / t_bat,
        })
    return out


# sharded-vs-single device counts for the D1 shape (1 = the no-sharding
# baseline, 4 = the scaling point — both forced host devices, CPU-safe)
D1_DEVICE_COUNTS = (1, 4)
# the join-heavy D1 subset; MUST mirror bench_sharded_prog.D1_QUERIES
# (the prog can't be imported here — its module body parses sys.argv and
# forces the device count before importing jax)
D1_QUERIES = ("Q2", "Q7", "Q9", "J1")
# the 4-device wall-time win needs enough data for the smaller per-shard
# sorts to amortise the mesh dispatch overhead (on a single-core host the
# whole win IS the O(n log^2 n) bitonic work reduction); below this scale
# the D2 assert is skipped and only the structural claims are checked
D2_WALL_WIN_MIN_SCALE = 8


def bench_sharded(scale: int, repeats: int) -> list[dict]:
    """D1 + D2: the sharded engine vs the single-device engine on the
    LUBM join-heavy (D1) and subject-star (D2) queries, at forced host
    device counts 1 and 4.

    Each device count runs in a SUBPROCESS (bench_sharded_prog.py) so XLA
    can be told the device count before jax initialises. Asserts the
    structural wins at 4 devices so a sharding regression fails the bench
    (and the distributed-smoke CI job running it):

      * D1 — per-shard max join bucket strictly below the single-device
        bucket on the join-heavy queries;
      * D2 — the subject-star queries emit ZERO shuffle collectives (the
        partitioning-aware lowering proves both join inputs co-located),
        and at least two D-series queries run FASTER on the 4-device mesh
        than on the 1-device mesh (map-side joins + collective/compute
        overlap turn the shard count into wall-clock, not just memory).
    """
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(root, "src")
    by_dev: dict[int, list[dict]] = {}
    for n_dev in D1_DEVICE_COUNTS:
        proc = subprocess.run(
            [sys.executable,
             os.path.join(root, "benchmarks", "bench_sharded_prog.py"),
             str(n_dev), str(scale), str(repeats)],
            capture_output=True, text=True, timeout=1200, env=env,
        )
        assert proc.returncode == 0, (
            f"D1 prog failed at n_dev={n_dev}:\n{proc.stdout}\n"
            f"{proc.stderr}"
        )
        payload = next(
            line for line in proc.stdout.splitlines()
            if line.startswith("BENCH_JSON: ")
        )
        by_dev[n_dev] = json.loads(payload[len("BENCH_JSON: "):])["records"]
    d1_set = set(D1_QUERIES)
    out = []
    wall_wins = []
    for rec1, rec4 in zip(*(by_dev[d] for d in D1_DEVICE_COUNTS)):
        assert rec1["query"] == rec4["query"]
        name = rec4["query"]
        if name in d1_set:
            assert (
                rec4["per_shard_max_bucket"] < rec4["single_max_bucket"]
            ), (
                f"D1 {name}: per-shard bucket "
                f"{rec4['per_shard_max_bucket']} not below single-device "
                f"{rec4['single_max_bucket']}"
            )
        else:  # D2 subject-star: zero emitted collectives on the mesh
            assert rec4["shuffles_emitted"] == 0, (
                f"D2 {name}: emitted {rec4['shuffles_emitted']} shuffles"
            )
        if rec4["sharded_ms"] < rec1["sharded_ms"]:
            wall_wins.append(name)
        tag = "D1" if name in d1_set else "D2"
        out.append({
            "query": f"{tag}-{name}",
            "rows": rec4["rows"],
            "sharded_1dev_ms": rec1["sharded_ms"],
            "sharded_4dev_ms": rec4["sharded_ms"],
            "single_ms": rec4["single_ms"],
            "single_max_bucket": rec4["single_max_bucket"],
            "per_shard_max_bucket": rec4["per_shard_max_bucket"],
            "shuffles_emitted": rec4["shuffles_emitted"],
            "shuffles_elided": rec4["shuffles_elided"],
            "broadcast_joins": rec4["broadcast_joins"],
        })
    if scale >= D2_WALL_WIN_MIN_SCALE:
        assert len(wall_wins) >= 2, (
            f"D2: only {wall_wins} ran faster at 4 devices than at 1 "
            f"(need >= 2 of the D-series at scale {scale})"
        )
    else:
        print(f"# D2 wall-time assert skipped (scale {scale} < "
              f"{D2_WALL_WIN_MIN_SCALE}); wins so far: {wall_wins}")
    return out


def bench_optimizer(store) -> list[dict]:
    """Greedy vs statistics-driven join order on the J1/J2 shapes.

    Asserts the optimizer win (strictly smaller max join bucket, same
    rows) so a planner regression turns the benchmark red.
    """
    out = []
    for name, text in lubm.J_QUERIES.items():
        greedy = QueryEngine(store, optimize=False)
        stats = QueryEngine(store)
        pg = greedy.prepare(text)
        rows_g = pg.run()
        ps = stats.prepare(text)
        rows_s = ps.run()
        assert len(rows_g) == len(rows_s), name
        assert rows_s.stats.peak_join_bucket < rows_g.stats.peak_join_bucket, (
            f"{name}: optimizer no longer shrinks the max join bucket "
            f"({rows_s.stats.peak_join_bucket} vs "
            f"{rows_g.stats.peak_join_bucket})"
        )
        t_g = _time(lambda: pg.run(), 3)
        t_s = _time(lambda: ps.run(), 3)
        out.append({
            "query": f"{name}-joinorder",
            "rows": len(rows_s),
            "greedy_max_bucket": rows_g.stats.peak_join_bucket,
            "stats_max_bucket": rows_s.stats.peak_join_bucket,
            "greedy_ms": t_g * 1e3,
            "stats_ms": t_s * 1e3,
        })
    return out


def bench_backend(repeats: int, seed: int = 0) -> list[dict]:
    """S1: MR vs matrix join backend on the skewed-predicate shape.

    Both engines execute the SAME plan (same join order, same buckets) —
    only the physical join algebra differs. Asserts that the cost-based
    optimizer routes S1's hot-key join to the matrix backend from the
    statistics alone (no override), that both backends return identical
    rows, and reports the warm DEVICE-side timing of each: S1 returns
    20k rows, and decoding them to host dicts costs the same for both
    backends while dwarfing the join itself, so the timed section is the
    compiled dispatch up to block_until_ready, not the decode.
    """
    from repro.sparql.engine import ExecStats

    store = lubm.generate(scale=1, seed=seed, skew_shapes=True)
    out = []
    for name, text in lubm.S_QUERIES.items():
        auto = QueryEngine(store)
        chosen = auto._build_program(
            auto.prepare(text).query
        ).plan.join_backends
        assert "matrix" in chosen, (
            f"{name}: optimizer chose {chosen}, expected the matrix "
            "backend from selectivity x skew statistics"
        )
        mr = QueryEngine(store, join_backend="mr")
        mx = QueryEngine(store, join_backend="matrix")
        p_mr, p_mx = mr.prepare(text), mx.prepare(text)
        rows_mr, rows_mx = p_mr.run(), p_mx.run()
        key = lambda rs: sorted(
            tuple(sorted(d.items())) for d in rs.rows
        )
        assert key(rows_mr) == key(rows_mx), f"{name}: backend mismatch"
        warm = p_mx.run()
        assert warm.stats.n_compiles == 0 and warm.stats.n_dispatches == 1

        def device_run(engine, prepared):
            rel = engine._execute_program(prepared._program, ExecStats())
            rel.cols.block_until_ready()

        t_mr = _time(lambda: device_run(mr, p_mr), repeats)
        t_mx = _time(lambda: device_run(mx, p_mx), repeats)
        out.append({
            "query": f"{name}-backend",
            "rows": len(rows_mx),
            "chosen_backend": "matrix",
            "mr_ms": t_mr * 1e3,
            "matrix_ms": t_mx * 1e3,
            "matrix_speedup": t_mr / t_mx,
        })
    return out


def bench_updates(scale: int, repeats: int, seed: int = 0) -> dict:
    """W1: the live-update path — ingest rate, warm-query latency across
    writes, and compaction.

    Sweeps insert_triples batch sizes for triples/sec, then warms the F1
    filter shape, applies inserts sized within the warm pattern's bucket
    headroom (reusing existing dictionary terms, so neither the scan
    buckets nor the pow-2 numeric table change shape) plus a few deletes
    of original base rows, and measures warm latency before the writes,
    after the writes, and after compact(). Asserts the acceptance
    property: the previously-warm shape re-runs at 0 compiles / 1
    dispatch after writes AND after compaction, and its rows equal a
    store rebuilt from scratch from the post-update triples.
    """
    from repro.core.planner import TriplePattern
    from repro.sparql.store import store_from_string_triples

    store = lubm.generate(scale=scale, seed=seed)

    # ingest-rate sweep: fresh subjects/objects under a bench-only
    # predicate, so the query shapes below are untouched
    ingest = []
    k = 0
    for batch in (64, 256, 1024):
        rows = []
        for _ in range(batch):
            rows.append((f"<w1:s{k}>", "<w1:ingest>", f"<w1:o{k}>"))
            k += 1
        t0 = time.perf_counter()
        applied = store.insert_triples(rows)
        dt = time.perf_counter() - t0
        assert applied == batch
        ingest.append({
            "batch_size": batch,
            "ms": dt * 1e3,
            "triples_per_s": batch / dt,
        })

    eng = QueryEngine(store)
    text = EXTRA_QUERIES["F1"]
    pq = eng.prepare(text)
    pq.run()  # calibrate + compile
    warm0 = pq.run()
    assert warm0.stats.n_compiles == 0 and warm0.stats.n_dispatches == 1
    t_before = _time(lambda: pq.run(), repeats)

    # writes sized within the warm name-pattern's bucket headroom, built
    # from existing terms only (cross-pairing professors with other
    # professors' names) so no dictionary growth can force a recompile
    d = store.dictionary
    name_tp = TriplePattern("?p", f"<{lubm.UB}name>", "?n")
    matches = store.match_rows(name_tp)
    headroom = store.scan_capacity(name_tp) - len(matches)
    have = {(int(s), int(o)) for s, _, o in matches}
    pid = d.lookup(f"<{lubm.UB}name>")
    new_rows = []
    for s, _, _ in matches:
        o = int(matches[(len(new_rows) * 7 + 3) % len(matches)][2])
        if (int(s), o) not in have and len(new_rows) < max(0, headroom - 2):
            new_rows.append(
                (d.decode(int(s)), d.decode(pid), d.decode(o)))
            have.add((int(s), o))
    inserted = store.insert_triples(new_rows)
    deleted = store.delete_triples([
        (d.decode(int(s)), d.decode(int(p)), d.decode(int(o)))
        for s, p, o in matches[:2]
    ])
    warm1 = pq.run()
    assert warm1.stats.n_compiles == 0 and warm1.stats.n_dispatches == 1, (
        "W1: warm shape recompiled after in-headroom writes "
        f"({warm1.stats.n_compiles} compiles)"
    )
    t_after_writes = _time(lambda: pq.run(), repeats)
    ws_before_compact = store.write_stats()

    t0 = time.perf_counter()
    store.compact()
    compact_ms = (time.perf_counter() - t0) * 1e3
    warm2 = pq.run()
    assert warm2.stats.n_compiles == 0 and warm2.stats.n_dispatches == 1, (
        "W1: warm shape recompiled after compaction "
        f"({warm2.stats.n_compiles} compiles)"
    )
    t_after_compact = _time(lambda: pq.run(), repeats)

    # differential acceptance: post-update rows == a store rebuilt from
    # scratch from the effective triples
    rebuilt = store_from_string_triples(sorted(
        (d.decode(int(s)), d.decode(int(p)), d.decode(int(o)))
        for s, p, o in store.triples
    ))
    key = lambda rows: sorted(tuple(sorted(r.items())) for r in rows)
    assert key(warm2.rows) == key(QueryEngine(rebuilt).query(text)), (
        "W1: post-update results diverge from a rebuilt store"
    )

    return {
        "query": "W1",
        "rows": len(warm2.rows),
        "ingest": ingest,
        "inserted": inserted,
        "deleted": deleted,
        "warm_ms_before_writes": t_before * 1e3,
        "warm_ms_after_writes": t_after_writes * 1e3,
        "warm_ms_after_compact": t_after_compact * 1e3,
        "compact_ms": compact_ms,
        "write_stats_before_compact": ws_before_compact,
        "write_stats_after_compact": store.write_stats(),
        "warm_cache_preserved": True,  # asserted above
    }


def bench(scale: int = 2, repeats: int = 20, seed: int = 0) -> list[dict]:
    store = lubm.generate(scale=scale, seed=seed, join_shapes=True)
    eager = QueryEngine(store, compiled=False)
    compiled = QueryEngine(store)
    out = []
    queries = {**lubm.QUERIES, **EXTRA_QUERIES, **lubm.J_QUERIES}
    for name, text in queries.items():
        # warm both: the eager jit cache and the compiled plan cache
        rows_e = eager.query(text)
        rows_c = compiled.query(text)
        assert len(rows_e) == len(rows_c), name
        t_eager = _time(lambda: eager.query(text), repeats)
        t_compiled = _time(lambda: compiled.query(text), repeats)
        out.append({
            "query": name,
            "rows": len(rows_c),
            "eager_ms": t_eager * 1e3,
            "compiled_ms": t_compiled * 1e3,
            "speedup": t_eager / t_compiled,
        })
    out.extend(bench_optimizer(store))
    out.extend(bench_batched(store, repeats))
    out.append({"plan_cache": compiled.cache_stats(),
                "scan_cache": store.scan_cache_stats()})
    return out


def main() -> None:
    args = [a for a in sys.argv[1:]]
    quick = "--quick" in args
    sharded_only = "--sharded-only" in args
    pos = [a for a in args if not a.startswith("--")]
    # --sharded-only runs at the D2 scale: big enough that the 4-device
    # mesh's smaller per-shard sorts beat the 1-device mesh on wall time
    scale = int(pos[0]) if pos else (
        1 if quick else 96 if sharded_only else 2
    )
    repeats = int(pos[1]) if len(pos) > 1 else (
        3 if quick else 5 if sharded_only else 20
    )
    sharded_records = []
    if not sharded_only:
        print(f"# repeated (warm) LUBM queries, scale={scale}, "
              f"{repeats} repeats: eager vs compiled one-dispatch pipeline")
        print("query,rows,eager_ms,compiled_ms,speedup")
        rows = bench(scale=scale, repeats=repeats)
        batched_records = []
        for r in rows:
            if "throughput_x" in r:
                batched_records.append(r)
                print(f"# {r['query']}: {r['n_queries']} same-shape warm "
                      f"queries, width={r['batch_width']}, "
                      f"stacked_dispatches={r['stacked_dispatches']}, "
                      f"sequential_ms={r['sequential_ms']:.2f} "
                      f"stacked_ms={r['stacked_ms']:.2f} "
                      f"throughput={r['throughput_x']:.2f}x")
            elif "speedup" in r:
                print(f"{r['query']},{r['rows']},{r['eager_ms']:.2f},"
                      f"{r['compiled_ms']:.2f},{r['speedup']:.2f}")
            elif "query" in r:
                print(f"# {r['query']}: rows={r['rows']} "
                      f"greedy_max_bucket={r['greedy_max_bucket']} "
                      f"stats_max_bucket={r['stats_max_bucket']} "
                      f"greedy_ms={r['greedy_ms']:.2f} "
                      f"stats_ms={r['stats_ms']:.2f}")
            else:
                print(f"# {r}")
        # batched-throughput artifact (CI uploads it; see .github/workflows)
        with open("BENCH_4.json", "w") as f:
            json.dump({"scale": scale, "repeats": repeats,
                       "batched": batched_records}, f, indent=2)
        print("# wrote BENCH_4.json")
        # S1: MR vs matrix physical join algebra on the skewed shape
        backend_records = bench_backend(repeats)
        for r in backend_records:
            print(f"# {r['query']}: rows={r['rows']} "
                  f"chosen={r['chosen_backend']} "
                  f"mr_ms={r['mr_ms']:.2f} matrix_ms={r['matrix_ms']:.2f} "
                  f"matrix_speedup={r['matrix_speedup']:.2f}x")
        with open("BENCH_6.json", "w") as f:
            json.dump({"repeats": repeats,
                       "backend": backend_records}, f, indent=2)
        print("# wrote BENCH_6.json")
        # W1: live updates — ingest rate, warm latency across writes and
        # compaction, warm-cache-preserved + differential assertions
        w1 = bench_updates(scale, repeats)
        for rec in w1["ingest"]:
            print(f"# W1 ingest: batch={rec['batch_size']} "
                  f"{rec['triples_per_s']:.0f} triples/s")
        print(f"# W1: rows={w1['rows']} inserted={w1['inserted']} "
              f"deleted={w1['deleted']} "
              f"warm_before={w1['warm_ms_before_writes']:.2f}ms "
              f"warm_after_writes={w1['warm_ms_after_writes']:.2f}ms "
              f"warm_after_compact={w1['warm_ms_after_compact']:.2f}ms "
              f"compact={w1['compact_ms']:.2f}ms")
        with open("BENCH_7.json", "w") as f:
            json.dump({"scale": scale, "repeats": repeats,
                       "updates": w1}, f, indent=2)
        print("# wrote BENCH_7.json")
    # D1 + D2: sharded vs single-device execution, 1 vs 4 forced host
    # devices. Runs on CPU too (subprocesses force the device count);
    # prints the shard-count scaling and asserts the per-shard bucket win
    # (D1) and the zero-shuffle subject-star + 4-device wall-time win (D2).
    sharded_records = bench_sharded(scale, repeats)
    for r in sharded_records:
        print(f"# {r['query']}: rows={r['rows']} "
              f"single_ms={r['single_ms']:.2f} "
              f"sharded_1dev_ms={r['sharded_1dev_ms']:.2f} "
              f"sharded_4dev_ms={r['sharded_4dev_ms']:.2f} "
              f"per_shard_max_bucket={r['per_shard_max_bucket']} "
              f"single_max_bucket={r['single_max_bucket']} "
              f"shuffles={r['shuffles_emitted']}e/"
              f"{r['shuffles_elided']}x/{r['broadcast_joins']}b")
    with open("BENCH_5.json", "w") as f:
        json.dump({"scale": scale, "repeats": repeats,
                   "device_counts": list(D1_DEVICE_COUNTS),
                   "sharded": sharded_records}, f, indent=2)
    print("# wrote BENCH_5.json")
    # D2 artifact: the shuffle-elision scaling story on its own — which
    # queries beat the 1-device mesh at 4 devices, and the per-query
    # emitted/elided/broadcast strategy counts
    wins = [r["query"] for r in sharded_records
            if r["sharded_4dev_ms"] < r["sharded_1dev_ms"]]
    with open("BENCH_8.json", "w") as f:
        json.dump({"scale": scale, "repeats": repeats,
                   "device_counts": list(D1_DEVICE_COUNTS),
                   "wall_time_wins_4dev": wins,
                   "star_queries_zero_emitted": [
                       r["query"] for r in sharded_records
                       if r["shuffles_emitted"] == 0
                   ],
                   "records": sharded_records}, f, indent=2)
    print(f"# wrote BENCH_8.json ({len(wins)} 4-device wall-time wins)")


if __name__ == "__main__":
    main()
