"""Open-loop serving tail latency: the two-stage pipelined server vs the
synchronous batcher, under Poisson arrivals on warm LUBM shapes.

A closed-loop benchmark (fire, wait, fire) hides queueing: a slow server
simply slows the generator down, and tail latency looks flat. This
generator is OPEN-LOOP — arrival times are drawn from a Poisson process at
a fixed rate and requests fire at their scheduled instants no matter how
the server is doing — so saturation shows up where production sees it: in
p99/p999 latency, not in a throughput figure. Latency is measured from the
SCHEDULED arrival, so client-pool queueing counts against the server.

The sweep records, per rate: p50/p99/p999 latency, achieved qps, dropped
requests, and the device-idle fraction (1 - Δengine.device_time_s / wall —
how long the accelerator sat waiting on host work). The headline
comparison runs sync (decode_workers=0: decode inline on the batcher
thread) vs pipelined (decode pool overlaps dispatch k+1 with decode k) at
a saturating rate and, in full mode, FAILS unless pipelined p99 improves
by >= 1.3x. The padding sub-bench asserts (in every mode) that cross-shape
padded stacking strictly reduces stacked-dispatch count on a mixed-shape
workload without changing any decoded rows. Everything lands in
BENCH_9.json (the serving-smoke CI job uploads it).

The observability sub-bench (`bench_obs`, also runnable alone via
`--obs-only` — the obs-smoke CI job) runs a traced burst and reports the
per-phase latency breakdown (parse/optimize/compile/dispatch/transfer/
decode seconds from the trace ring), gates the Chrome trace-event export
against docs/trace_schema.json and the Prometheus exposition against its
own parser, asserts zero leaked (open) spans, and guards the warm-path
cost of tracing: p50 with a Tracer attached must stay within 3% of p50
without one (full mode; quick mode only sanity-bounds it). Lands in
BENCH_10.json.

    PYTHONPATH=src python -m benchmarks.bench_serving [scale]
    PYTHONPATH=src python -m benchmarks.bench_serving --quick
    PYTHONPATH=src python -m benchmarks.bench_serving --quick --obs-only
"""
from __future__ import annotations

import json
import os
import sys
import time
from concurrent.futures import ThreadPoolExecutor

import numpy as np

from repro.obs import (
    Tracer,
    parse_prometheus,
    phase_totals,
    quantile_from_samples,
    validate_chrome_events,
)
from repro.sparql import lubm
from repro.sparql.engine import QueryEngine
from repro.serve.sparql_server import SPARQLServer

# Two structurally identical chain families over predicates of very
# different cardinality (memberOf ~50x subOrganizationOf, worksFor ~7x):
# each family is one PlanShape; their pow-2 scan caps differ, so only
# cross-shape padding can merge them into one stacked dispatch.
PAD_FAMILIES = [
    lubm.PREFIX + """SELECT ?x ?u WHERE {
        ?x ub:memberOf ?d .
        ?d ub:subOrganizationOf ?u .
    }""",
    lubm.PREFIX + """SELECT ?x ?u WHERE {
        ?x ub:worksFor ?d .
        ?d ub:subOrganizationOf ?u .
    }""",
]


def serving_texts(n_variants: int = 8) -> list[str]:
    """The mixed warm workload: one FILTER-varied same-shape family (the
    runtime-constant stacking case) plus the two pad families."""
    filtered = [
        lubm.PREFIX + f"""SELECT ?p ?n WHERE {{
            ?p a ub:FullProfessor .
            ?p ub:name ?n .
            FILTER (?n != "prof_0_{k % 8}_{k // 8}")
        }}"""
        for k in range(n_variants)
    ]
    return filtered + PAD_FAMILIES


def warm(srv: SPARQLServer, texts: list[str]) -> None:
    """Pay calibration/compile for every shape, then one mixed round so
    the stacked (and padded) executables exist before measurement."""
    for t in texts:
        srv.query(t)
    with ThreadPoolExecutor(max_workers=len(texts)) as pool:
        list(pool.map(srv.query, texts * 2))


def measure_capacity(srv: SPARQLServer, texts: list[str],
                     n: int = 200) -> float:
    """Warm closed-loop throughput (16 concurrent clients) — the anchor
    the open-loop sweep rates are expressed against."""
    reqs = [texts[i % len(texts)] for i in range(n)]
    t0 = time.perf_counter()
    with ThreadPoolExecutor(max_workers=16) as pool:
        list(pool.map(srv.query, reqs))
    return n / (time.perf_counter() - t0)


def open_loop(srv: SPARQLServer, texts: list[str], rate: float | None,
              n_req: int, seed: int = 0,
              max_clients: int = 256) -> dict:
    """One open-loop run: Poisson arrivals at `rate` qps, `n_req` requests.

    The generator thread sleeps to each scheduled arrival and hands the
    request to a client pool; latency counts from the SCHEDULED arrival,
    so neither a saturated server nor a saturated client pool can slow
    the arrival process down (the open-loop property).

    `rate=None` is the saturating limit (arrival rate -> infinity): every
    request arrives at t=0 and latency is position-in-drain, so p99 reads
    as burst drain time — the stable way to compare two servers at
    saturation, immune to where the knee of the latency curve sits."""
    if rate is None:
        sched = np.zeros(n_req)
    else:
        rng = np.random.default_rng(seed)
        sched = np.cumsum(rng.exponential(1.0 / rate, size=n_req))
    lat: list = [None] * n_req
    errs: list = [None] * n_req
    eng = srv.engine
    busy0 = eng.device_time_s
    pool = ThreadPoolExecutor(max_workers=max_clients)
    t0 = time.perf_counter()

    def fire(i: int, text: str) -> None:
        t_arr = t0 + sched[i]
        try:
            srv.query(text)
            lat[i] = time.perf_counter() - t_arr
        except Exception as e:  # dropped (timeout / failure): recorded
            errs[i] = e

    futs = []
    for i in range(n_req):
        delay = t0 + sched[i] - time.perf_counter()
        if delay > 0:
            time.sleep(delay)
        futs.append(pool.submit(fire, i, texts[i % len(texts)]))
    for f in futs:
        f.result()
    wall = time.perf_counter() - t0
    pool.shutdown()
    ls = np.asarray([x for x in lat if x is not None])
    busy = eng.device_time_s - busy0
    return {
        "offered_qps": rate if rate is not None else "burst",
        "n_requests": n_req,
        "dropped": sum(1 for e in errs if e is not None),
        "achieved_qps": len(ls) / wall if wall else 0.0,
        "p50_ms": float(np.percentile(ls, 50) * 1e3),
        "p99_ms": float(np.percentile(ls, 99) * 1e3),
        "p999_ms": float(np.percentile(ls, 99.9) * 1e3),
        "device_idle_frac": float(max(0.0, 1.0 - busy / wall)),
        "wall_s": wall,
    }


def make_server(store, decode_workers: int) -> SPARQLServer:
    return SPARQLServer(
        QueryEngine(store),
        max_batch=16,
        max_wait_s=0.002,
        decode_workers=decode_workers,
    )


def bench_serving(store, quick: bool) -> dict:
    """The headline: sync vs pipelined under the same open-loop traffic.

    Each mode gets a Poisson rate sweep (the latency-vs-load curve, rates
    anchored to a warm closed-loop capacity probe) and then a saturating
    BURST run — every request arrives at t=0, so p99 reads as burst drain
    time. The burst is where the comparison is made: a Poisson point near
    the estimated knee is exquisitely sensitive to where the knee really
    is, while the rate->infinity limit saturates both servers by
    construction. Each server is burned in (one closed-loop round + one
    discarded burst) after warm() so stacked-width compiles triggered by
    measurement-time batch shapes don't land inside a measured run."""
    texts = serving_texts()
    n_burst = 96 if quick else 256
    probe = make_server(store, decode_workers=2)
    warm(probe, texts)
    cap = measure_capacity(probe, texts, n=60 if quick else 200)
    probe.close()
    print(f"# warm closed-loop capacity ~{cap:.0f} qps")
    fracs = [0.5, 1.2] if quick else [0.3, 0.6, 0.9, 1.2]
    out: dict = {"capacity_qps": cap, "modes": {}}
    for mode, workers in (("sync", 0), ("pipelined", 2)):
        srv = make_server(store, decode_workers=workers)
        warm(srv, texts)
        measure_capacity(srv, texts, n=48)  # burn-in: width compiles
        open_loop(srv, texts, None, n_burst, max_clients=n_burst)
        sweep = []
        for frac in fracs:
            rate = max(5.0, cap * frac)
            n_req = int(max(64, min(1200, rate * (2 if quick else 5))))
            rec = open_loop(srv, texts, rate, n_req)
            rec["load_frac"] = frac
            sweep.append(rec)
            print(f"# {mode} @ {rate:6.0f} qps (x{frac}): "
                  f"p50={rec['p50_ms']:.1f}ms p99={rec['p99_ms']:.1f}ms "
                  f"p999={rec['p999_ms']:.1f}ms "
                  f"idle={rec['device_idle_frac']:.2f} "
                  f"dropped={rec['dropped']}")
        burst = open_loop(srv, texts, None, n_burst, max_clients=n_burst)
        print(f"# {mode} burst({n_burst}): "
              f"p50={burst['p50_ms']:.1f}ms p99={burst['p99_ms']:.1f}ms "
              f"drain={burst['wall_s'] * 1e3:.0f}ms "
              f"idle={burst['device_idle_frac']:.2f} "
              f"dropped={burst['dropped']}")
        st = srv.stats()
        out["modes"][mode] = {
            "sweep": sweep,
            "burst": burst,
            "stacked_dispatches": st["batched"]["stacked_dispatches"],
            "queries_per_dispatch": st["batched"]["queries_per_dispatch"],
            "padding": st["batched"]["padding"],
            "pipeline": {
                k: v for k, v in st["pipeline"].items() if k != "decode"
            },
            "decode": st["pipeline"]["decode"],
        }
        srv.close()
        # structural CI gates (quick mode runs on CPU: timing-free)
        assert st["batched"]["stacked_dispatches"] > 0, (
            f"{mode}: no stacked dispatches — batching is broken"
        )
        assert burst["dropped"] == 0 and all(
            r["dropped"] == 0 for r in sweep
        ), f"{mode}: open-loop run dropped requests"
    sat_sync = out["modes"]["sync"]["burst"]
    sat_pipe = out["modes"]["pipelined"]["burst"]
    ratio = sat_sync["p99_ms"] / sat_pipe["p99_ms"]
    out["saturating_p99_ratio"] = ratio
    print(f"# saturating p99: sync={sat_sync['p99_ms']:.1f}ms "
          f"pipelined={sat_pipe['p99_ms']:.1f}ms -> {ratio:.2f}x")
    if not quick:
        assert ratio >= 1.3, (
            f"pipelined server must improve saturating p99 by >=1.3x "
            f"(got {ratio:.2f}x)"
        )
    return out


def bench_padding(store) -> dict:
    """Structural acceptance: cross-shape padding strictly reduces the
    stacked-dispatch count on a mixed-shape batch, with identical rows.
    One forced join backend keeps the two families' plan DAGs identical
    (per-slot cost-based picks could otherwise split the pad bucket)."""
    def rows_key(rs):
        return sorted(tuple(sorted(r.items())) for r in rs.rows)

    texts = [t for t in PAD_FAMILIES for _ in range(8)]
    res = {}
    for flag in (False, True):
        eng = QueryEngine(store, join_backend="mr", pad_stacking=flag)
        ps = [eng.prepare(t) for t in texts]
        for p in ps:
            p.run()  # warm every member shape
        d0 = eng.stacked_dispatches
        t0 = time.perf_counter()
        batch = eng.run_batch(ps)
        dt = time.perf_counter() - t0
        res[flag] = {
            "dispatches": eng.stacked_dispatches - d0,
            "rows": [rows_key(r) for r in batch],
            "batch_ms": dt * 1e3,
            "eng": eng,
        }
    off, on = res[False], res[True]
    assert on["dispatches"] < off["dispatches"], (
        f"padding must strictly reduce stacked dispatches "
        f"({off['dispatches']} -> {on['dispatches']})"
    )
    assert off["rows"] == on["rows"], "padding changed decoded rows"
    eng = on["eng"]
    rec = {
        "n_queries": len(texts),
        "n_shapes": 2,
        "dispatches_unpadded": off["dispatches"],
        "dispatches_padded": on["dispatches"],
        "batch_ms_unpadded": off["batch_ms"],
        "batch_ms_padded": on["batch_ms"],
        "padded_groups": eng.padded_groups,
        "pad_rejects": eng.pad_rejects,
        "waste_ratio": (
            (eng.padded_cells - eng.real_cells) / eng.real_cells
            if eng.real_cells else 0.0
        ),
    }
    print(f"# padding: {rec['n_queries']} queries / 2 shapes -> "
          f"{off['dispatches']} dispatches unpadded, "
          f"{on['dispatches']} padded "
          f"(waste={rec['waste_ratio']:.2f})")
    return rec


def _warm_p50(eng: QueryEngine, texts: list[str], n_iter: int,
              tracer: Tracer | None) -> float:
    """p50 warm-path latency of single prepared runs, with or without a
    per-run trace — same engine, same compiled caches, so the only
    difference between the two calls is the tracing bookkeeping."""
    pqs = [eng.prepare(t) for t in texts]
    for pq in pqs:
        pq.run()  # all shapes warm before either timed pass
    lats = []
    for i in range(n_iter):
        pq = pqs[i % len(pqs)]
        tr = tracer.new_trace("query") if tracer is not None else None
        t0 = time.perf_counter()
        pq.run(trace=tr)
        lats.append(time.perf_counter() - t0)
        if tracer is not None:
            tracer.finish(tr)
    return quantile_from_samples(lats, 0.5)


def bench_obs(store, quick: bool) -> dict:
    """Observability acceptance: a traced open-loop burst through the
    full pipelined server, then three structural gates (trace-export
    schema, Prometheus exposition validity, zero leaked spans) and the
    tracing-overhead guard on the warm path."""
    texts = serving_texts()
    tracer = Tracer(ring_size=1024, slow_ms=250.0)
    srv = SPARQLServer(
        QueryEngine(store, tracer=tracer),
        max_batch=16,
        max_wait_s=0.002,
        decode_workers=2,
    )
    warm(srv, texts)
    n_burst = 64 if quick else 192
    burst = open_loop(srv, texts, None, n_burst, max_clients=n_burst)
    traces = srv.recent_traces()
    phases = phase_totals(traces)
    total = phases.get("query", 0.0)
    breakdown = {
        k: {"seconds": v, "share": v / total if total else 0.0}
        for k, v in sorted(phases.items())
    }
    print("# phase breakdown (traced burst):")
    for k, rec in breakdown.items():
        print(f"#   {k:10s} {rec['seconds'] * 1e3:9.1f}ms "
              f"({rec['share']:5.1%} of query span time)")

    # gate 1: every span in the ring closed — nothing leaked under
    # concurrency, batching, padding or decode hand-off
    open_spans = tracer.open_span_count()
    assert open_spans == 0, f"{open_spans} spans left open after burst"

    # gate 2: the Chrome export validates against the checked-in schema
    schema_path = os.path.join(
        os.path.dirname(__file__), "..", "docs", "trace_schema.json"
    )
    with open(schema_path) as f:
        schema = json.load(f)
    events = tracer.export_chrome()
    errs = validate_chrome_events(events, schema)
    assert not errs, f"trace export schema violations: {errs[:5]}"

    # gate 3: the exposition parses (grammar, histogram monotonicity,
    # +Inf == _count) and carries the serving counters
    prom = srv.render_prometheus()
    parsed = parse_prometheus(prom)
    for name in (
        "mapsq_requests_total",
        "mapsq_request_latency_seconds_bucket",
        "mapsq_stacked_dispatches_total",
        "mapsq_padding_padded_cells_total",
        "mapsq_plan_cache_hits_total",
        "mapsq_device_time_seconds_total",
    ):
        assert name in parsed, f"exposition missing {name}"
    n_slow = len(srv.slow_queries())
    srv.close()

    # overhead guard: tracing must be ~free on the warm path
    n_iter = 120 if quick else 400
    eng = QueryEngine(store)
    p50_off = _warm_p50(eng, texts, n_iter, tracer=None)
    p50_on = _warm_p50(eng, texts, n_iter, tracer=Tracer(ring_size=64))
    overhead = p50_on / p50_off - 1.0 if p50_off else 0.0
    print(f"# tracing overhead: p50 off={p50_off * 1e3:.3f}ms "
          f"on={p50_on * 1e3:.3f}ms -> {overhead:+.2%}")
    if quick:
        # CPU quick mode: timing too noisy for the 3% bar, sanity only
        assert overhead < 0.50, (
            f"tracing overhead {overhead:.1%} is not in the same ballpark"
        )
    else:
        assert overhead < 0.03, (
            f"tracing-on warm p50 exceeds the 3% overhead budget "
            f"({overhead:.2%})"
        )
    return {
        "burst": burst,
        "n_traces": len(traces),
        "n_chrome_events": len(events),
        "n_slow_queries": n_slow,
        "open_spans": open_spans,
        "phase_breakdown": breakdown,
        "tracing_overhead_p50": {
            "off_ms": p50_off * 1e3,
            "on_ms": p50_on * 1e3,
            "overhead_frac": overhead,
        },
    }


def main() -> None:
    args = sys.argv[1:]
    quick = "--quick" in args
    obs_only = "--obs-only" in args
    pos = [a for a in args if not a.startswith("--")]
    scale = int(pos[0]) if pos else (1 if quick else 2)
    print(f"# open-loop serving bench, LUBM scale={scale}, "
          f"{'quick' if quick else 'full'} mode"
          f"{' (obs only)' if obs_only else ''}")
    store = lubm.generate(scale=scale, seed=0)
    if not obs_only:
        padding = bench_padding(store)
        serving = bench_serving(store, quick)
        with open("BENCH_9.json", "w") as f:
            json.dump({
                "mode": "quick" if quick else "full",
                "scale": scale,
                "padding": padding,
                "serving": serving,
            }, f, indent=2)
        print("# wrote BENCH_9.json")
    obs = bench_obs(store, quick)
    with open("BENCH_10.json", "w") as f:
        json.dump({
            "mode": "quick" if quick else "full",
            "scale": scale,
            "obs": obs,
        }, f, indent=2)
    print("# wrote BENCH_10.json")


if __name__ == "__main__":
    main()
