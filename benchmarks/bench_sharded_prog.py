"""Subprocess body for the D1/D2 bench shapes: sharded vs single-device.

Runs in its own process so the host device count can be forced before jax
imports (bench_query.py spawns it at n_dev=1 and n_dev=4 and reports the
shard-count scaling). For every D-series query it measures the warm
per-query latency of both engines and records:

  * the max join bucket each engine compiled — the D1 structural claim
    (asserted by the caller at n_dev > 1) is that the PER-SHARD bucket
    sits strictly below the single-device bucket;
  * the shuffle strategy counts of the partitioning-aware lowering — the
    D2 claim (asserted HERE and by the caller) is that the subject-star
    queries emit ZERO shuffle collectives: both join inputs are already
    subject-hash co-partitioned, so the whole query is map-side joins.

Usage: bench_sharded_prog.py [n_devices] [scale] [repeats]
Emits one `BENCH_JSON: {...}` line on stdout.
"""
import json
import os
import sys
import time

N_DEV = int(sys.argv[1]) if len(sys.argv) > 1 else 4
SCALE = int(sys.argv[2]) if len(sys.argv) > 2 else 1
REPEATS = int(sys.argv[3]) if len(sys.argv) > 3 else 3

os.environ["XLA_FLAGS"] = (
    f"--xla_force_host_platform_device_count={N_DEV} "
    + os.environ.get("XLA_FLAGS", "")
)

import jax  # noqa: E402

from repro.sparql import lubm  # noqa: E402
from repro.sparql.engine import QueryEngine, ShardedQueryEngine  # noqa: E402
from repro.sparql.sharded_store import shard_store  # noqa: E402

# D1: join-heavy shapes (the per-shard bucket-shrink claim)
D1_QUERIES = ("Q2", "Q7", "Q9", "J1")
# D2: subject-star shapes — every join key is the shared subject variable,
# so the subject-hash partitioned scans are ALREADY aligned and the
# lowering elides every shuffle (0 emitted collectives, asserted below)
STAR_QUERIES = ("Q1", "Q4")


def _time(fn, repeat):
    """Best-of-repeat wall time: the min is the noise-robust statistic on
    a shared CPU box (a load spike inflates the mean but not the min)."""
    best = float("inf")
    for _ in range(repeat):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def main() -> None:
    assert jax.device_count() == N_DEV, (jax.device_count(), N_DEV)
    store = lubm.generate(scale=SCALE, seed=0, join_shapes=True)
    single = QueryEngine(store)
    sharded = ShardedQueryEngine(shard_store(store, N_DEV))
    queries = {**lubm.QUERIES, **lubm.J_QUERIES}
    records = []
    for name in D1_QUERIES + STAR_QUERIES:
        text = queries[name]
        pq_si = single.prepare(text)
        pq_sh = sharded.prepare(text)
        rows_si = pq_si.run()
        rows_sh = pq_sh.run()
        assert len(rows_si) == len(rows_sh), (name, len(rows_si),
                                              len(rows_sh))
        warm_si = pq_si.run()
        warm_sh = pq_sh.run()
        assert warm_sh.stats.n_dispatches == 1 and (
            warm_sh.stats.n_compiles == 0
        ), (name, warm_sh.stats)
        if name in STAR_QUERIES:
            assert warm_sh.stats.n_shuffles_emitted == 0, (
                f"D2 {name}: subject-star emitted "
                f"{warm_sh.stats.n_shuffles_emitted} shuffles, expected 0"
            )
        records.append({
            "query": name,
            "n_dev": N_DEV,
            "rows": len(rows_sh),
            "single_ms": _time(pq_si.run, REPEATS) * 1e3,
            "sharded_ms": _time(pq_sh.run, REPEATS) * 1e3,
            "single_max_bucket": warm_si.stats.peak_join_bucket,
            "per_shard_max_bucket": warm_sh.stats.peak_join_bucket,
            "shuffles_emitted": warm_sh.stats.n_shuffles_emitted,
            "shuffles_elided": warm_sh.stats.n_shuffles_elided,
            "broadcast_joins": warm_sh.stats.n_broadcast_joins,
        })
    print("BENCH_JSON: " + json.dumps({"n_dev": N_DEV, "scale": SCALE,
                                       "records": records}))


if __name__ == "__main__":
    main()
