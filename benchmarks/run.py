"""Benchmark entry point: one benchmark per paper artifact.

  bench_join     — Table 2 / Figure 2: join time per LUBM query,
                   MapSQ vs gStore/gStoreD stand-ins (+ speedups)
  bench_query    — repeated (warm-cache) LUBM queries: eager per-join
                   loop vs the compiled one-dispatch pipeline
  bench_scaling  — Figure 2(b)-style: MapSQ vs hash join as relation
                   size grows (the 'large dataset scale' claim)
  bench_kernels  — Pallas kernels vs their jnp references (micro)
  roofline       — §Roofline table from the dry-run artifacts (if present)
"""
from __future__ import annotations

import time

import numpy as np

import jax
import jax.numpy as jnp


def _time(fn, repeat=3) -> float:
    best = float("inf")
    for _ in range(repeat):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def bench_scaling() -> None:
    """MapSQ MR join vs CPU hash join over growing relations (zipf keys)."""
    from repro.core.relation import Relation
    from repro.core import mr_join as mj
    from repro.sparql.baseline import hash_join

    print("\n# Figure 2(b)-style scaling: rows,hash_ms,mapsq_ms,speedup")
    jit_join = jax.jit(mj.mr_join, static_argnames=("capacity",))
    rng = np.random.default_rng(0)
    for n in (1 << 12, 1 << 14, 1 << 16, 1 << 18):
        # ~uniform keys: E[matches per row] ~ 2, so output stays O(n)
        keys_l = rng.integers(0, n // 2, n).astype(np.int32)
        keys_r = rng.integers(0, n // 2, n).astype(np.int32)
        left = Relation.from_numpy(
            ("?k", "?a"), np.stack([keys_l, np.arange(n)], 1))
        right = Relation.from_numpy(
            ("?k", "?b"), np.stack([keys_r, np.arange(n)], 1))
        total = int(mj.mr_join_count(left, right))
        cap = 1 << max(1, (total - 1).bit_length())
        run = lambda: jit_join(left, right, capacity=cap)[0].cols\
            .block_until_ready()
        run()
        t_dev = _time(run)
        la, ra = np.asarray(left.cols), np.asarray(right.cols)
        t_cpu = _time(lambda: hash_join(("?k", "?a"), la, ("?k", "?b"), ra))
        print(f"{n},{t_cpu * 1e3:.2f},{t_dev * 1e3:.2f},"
              f"{t_cpu / t_dev:.2f}  (result rows: {total})")


def bench_kernels() -> None:
    """Pallas kernel micro-shapes vs pure-jnp references (interpret mode on
    CPU: correctness + call overhead, not TPU latency)."""
    from repro.kernels.bitonic_sort import ops as sort_ops
    from repro.kernels.pair_expand import ops as pe_ops
    from repro.kernels.segment_reduce import ops as sr_ops

    print("\n# kernels: name,n,us_per_call (interpret-mode on CPU)")
    k = jax.random.randint(jax.random.PRNGKey(0), (4096,), 0, 1 << 20)
    v = jnp.arange(4096, dtype=jnp.int32)
    run = lambda: sort_ops.sort_pairs(k, v)[0].block_until_ready()
    run()
    print(f"bitonic_sort,4096,{_time(run) * 1e6:.0f}")
    counts = jax.random.randint(jax.random.PRNGKey(1), (512,), 0, 8)
    prefix = jnp.cumsum(counts, dtype=jnp.int32)
    run = lambda: pe_ops.pair_expand(prefix, counts, 4096)[0]\
        .block_until_ready()
    run()
    print(f"pair_expand,512x8,{_time(run) * 1e6:.0f}")
    data = jax.random.normal(jax.random.PRNGKey(2), (2048, 64))
    ids = jnp.sort(jax.random.randint(jax.random.PRNGKey(3), (2048,), 0, 128))
    run = lambda: sr_ops.sorted_segment_sum(data, ids, 128)\
        .block_until_ready()
    run()
    print(f"segment_reduce,2048x64,{_time(run) * 1e6:.0f}")


def main() -> None:
    from benchmarks import bench_join, bench_query

    bench_join.main()
    bench_query.main()
    bench_scaling()
    bench_kernels()
    try:
        from benchmarks import roofline

        if roofline.load():
            print("\n(roofline dry-run artifacts present: "
                  "run `python -m benchmarks.roofline` for the full table)")
    except Exception:
        pass


if __name__ == "__main__":
    main()
